"""Pallas execution-mode detection shared by every kernel package.

The repo's kernels (`kernels.minplus`, `kernels.arrival`) are written to
run in two modes:

  * **compiled** — lowered by a real Pallas backend: Mosaic on TPU,
    Triton on GPU. This is where the fusion argument (one kernel
    invocation instead of hundreds of XLA primitives) actually buys
    wall time.
  * **interpret** — `pallas_call(..., interpret=True)`: the kernel body
    is traced as ordinary JAX ops with refs emulated, so it runs
    anywhere XLA runs (including CPU CI containers), bit-identical to
    the compiled semantics but with no fusion win.

Historically the minplus kernels hard-coded ``interpret = backend !=
"tpu"``. This module replaces that with one autodetected, probed
answer: `pallas_mode()` names the mode (``"mosaic"`` / ``"triton"`` /
``"interpret"``), verified by actually compiling a trivial kernel once
per process — a backend that *claims* Pallas support but fails to
lower falls back to interpret instead of crashing the sweep. Benchmarks
record the mode in their rows (results/roofline.json ``pallas_mode``)
so a "kernel" measurement is never mistaken for a compiled-mode one.

``REPRO_PALLAS_MODE=interpret`` forces interpret mode (used by CI to
pin the equivalence suites to the emulated path);
``REPRO_PALLAS_MODE=compiled`` skips the probe's fallback and raises if
compilation fails (debugging aid).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

ENV_VAR = "REPRO_PALLAS_MODE"

#: jax.default_backend() -> the Pallas lowering that serves it. XLA:CPU
#: has no compiled Pallas path in this JAX version (Triton-on-CPU is
#: probed anyway in case a newer runtime provides it — the probe, not
#: this table, is the source of truth).
_COMPILED_MODES = {"tpu": "mosaic", "gpu": "triton", "cuda": "triton",
                   "rocm": "triton"}


def _probe_compiled() -> bool:
    """Compile + run a trivial Pallas kernel with ``interpret=False``.
    Any failure (missing lowering, driver mismatch, unsupported op set)
    means the compiled mode is unusable on this host."""
    from jax.experimental import pallas as pl

    def _k(x_ref, o_ref):
        o_ref[:] = x_ref[:] + 1.0

    try:
        out = pl.pallas_call(
            _k,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            interpret=False,
        )(jnp.zeros((8, 128), jnp.float32))
        return bool(out[0, 0] == 1.0)
    except Exception:
        return False


@functools.lru_cache(maxsize=1)
def pallas_mode() -> str:
    """The Pallas execution mode for this process: ``"mosaic"``,
    ``"triton"`` or ``"interpret"`` (safe fallback). Probed once and
    cached; ``REPRO_PALLAS_MODE`` overrides."""
    forced = os.environ.get(ENV_VAR, "")
    if forced == "interpret":
        return "interpret"
    candidate = _COMPILED_MODES.get(jax.default_backend())
    if candidate is None and forced != "compiled":
        return "interpret"
    if _probe_compiled():
        return candidate or "triton"
    if forced == "compiled":
        raise RuntimeError(
            f"REPRO_PALLAS_MODE=compiled but the trivial Pallas probe "
            f"failed to compile on backend {jax.default_backend()!r}")
    return "interpret"


def use_interpret() -> bool:
    """True when kernels should pass ``interpret=True`` to
    `pallas_call` (no compiled Pallas backend on this host)."""
    return pallas_mode() == "interpret"
