"""Jit'd wrapper for the min-plus transition kernel.

`minplus_step` has the exact signature of the jnp oracle
(repro.core.dp.minplus_step_jnp) so the DP can swap implementations with a
flag. The execution mode is probed, not assumed: wherever
`repro.kernels.backend.pallas_mode` finds a working compiled lowering
(Mosaic on TPU, Triton on GPU — or Triton-on-CPU if the runtime grows
one) the kernels compile; everywhere else they run in interpret mode
(the kernel body traced as ordinary XLA ops).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backend import use_interpret

from .minplus import minplus_pallas
from .structured import minplus_structured_pallas


def _interpret() -> bool:
    return use_interpret()


def _pack(coeffs) -> jnp.ndarray:
    af, df, ac, dc = coeffs
    return jnp.stack([jnp.asarray(af, jnp.float32),
                      jnp.asarray(df, jnp.float32),
                      jnp.asarray(ac, jnp.float32),
                      jnp.asarray(dc, jnp.float32)])


def minplus_step(F: jnp.ndarray, yc_prev: jnp.ndarray, yc_cur: jnp.ndarray,
                 coeffs) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense O(N^2) transition kernel (the original HBM-light contraction)."""
    return minplus_pallas(F, yc_prev, yc_cur, _pack(coeffs),
                          interpret=_interpret())


def minplus_step_structured(F: jnp.ndarray, yc_prev: jnp.ndarray,
                            yc_cur: jnp.ndarray,
                            coeffs) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Structured O(N log N) transition kernel; requires non-increasing
    y_c vectors (guaranteed by `core.dp._stage_tables`). This is the
    ``transition="kernel"`` backend of the DP solvers."""
    return minplus_structured_pallas(F, yc_prev, yc_cur, _pack(coeffs),
                                     interpret=_interpret())
