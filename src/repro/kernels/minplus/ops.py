"""Jit'd wrapper for the min-plus transition kernel.

`minplus_step` has the exact signature of the jnp oracle
(repro.core.dp.minplus_step_jnp) so the DP can swap implementations with a
flag. On CPU the kernel runs in interpret mode (Python-level execution of
the kernel body); on TPU it compiles to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .minplus import minplus_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def minplus_step(F: jnp.ndarray, yc_prev: jnp.ndarray, yc_cur: jnp.ndarray,
                 coeffs) -> tuple[jnp.ndarray, jnp.ndarray]:
    af, df, ac, dc = coeffs
    params = jnp.stack([jnp.asarray(af, jnp.float32),
                        jnp.asarray(df, jnp.float32),
                        jnp.asarray(ac, jnp.float32),
                        jnp.asarray(dc, jnp.float32)])
    return minplus_pallas(F, yc_prev, yc_cur, params, interpret=_interpret())
