from .ops import minplus_step  # noqa: F401
