from .ops import minplus_step, minplus_step_structured  # noqa: F401
