"""Min-plus (tropical) DP transition kernel.

Computes, for every destination level j:

    out(j)    = min_i [ F(i) + T(i, j) ]
    arg(j)    = argmin_i [...]                       (first minimizer)
    T(i, j)   = af*(j-i)+ + df*(i-j)+ + ac*(ycc(j)-ycp(i))+ + dc*(ycp(i)-ycc(j))+

The transition matrix T is *generated in-registers* from index arithmetic
and two O(N) vectors — O(N^2) MXU/VPU work on O(N) HBM traffic, which is
the whole point of the kernel: the pure-jnp path materializes the (N, N)
matrix in memory every scan step.

Tiling: grid (j_blocks, i_blocks); j is parallel across the grid, i is the
innermost (arbitrary) dimension accumulated into the output block with the
standard revisit pattern. Blocks are (1, BLOCK) row vectors so the lane
dimension is 128-aligned for the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
# Large *positive* sentinel: padded source levels must never win the min.
_PAD_HI = 3.0e38


def _kernel(params_ref, f_ref, ycp_ref, ycc_ref, out_ref, arg_ref, *,
            n_valid: int, block: int):
    i_blk = pl.program_id(1)
    af = params_ref[0, 0]
    df = params_ref[0, 1]
    ac = params_ref[0, 2]
    dc = params_ref[0, 3]

    f = f_ref[0, :]                       # (block,) source levels i
    ycp = ycp_ref[0, :]                   # (block,)
    ycc = ycc_ref[0, :]                   # (block,) destination levels j

    ii = (i_blk * block
          + jax.lax.broadcasted_iota(jnp.float32, (block, block), 0))
    jj = (pl.program_id(0) * block
          + jax.lax.broadcasted_iota(jnp.float32, (block, block), 1))
    relu = lambda x: jnp.maximum(x, 0.0)
    trans = (af * relu(jj - ii) + df * relu(ii - jj)
             + ac * relu(ycc[None, :] - ycp[:, None])
             + dc * relu(ycp[:, None] - ycc[None, :]))
    vals = f[:, None] + trans
    # mask padded source levels
    vals = jnp.where(ii < n_valid, vals, _PAD_HI)

    local_min = jnp.min(vals, axis=0)
    local_arg = (i_blk * block + jnp.argmin(vals, axis=0)).astype(jnp.int32)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[0, :] = local_min
        arg_ref[0, :] = local_arg

    @pl.when(i_blk > 0)
    def _accum():
        cur = out_ref[0, :]
        better = local_min < cur              # strict: keep first minimizer
        out_ref[0, :] = jnp.where(better, local_min, cur)
        arg_ref[0, :] = jnp.where(better, local_arg, arg_ref[0, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_pallas(F: jnp.ndarray, yc_prev: jnp.ndarray, yc_cur: jnp.ndarray,
                   params: jnp.ndarray, interpret: bool | None = None):
    """F, yc_prev, yc_cur: (N,) float32; params: (4,) [af, df, ac, dc].
    ``interpret=None`` autodetects via `repro.kernels.backend`."""
    if interpret is None:
        from repro.kernels.backend import use_interpret
        interpret = use_interpret()
    n = F.shape[0]
    n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    pad = n_pad - n
    Fp = jnp.pad(F.astype(jnp.float32), (0, pad),
                 constant_values=_PAD_HI)[None, :]
    ycp = jnp.pad(yc_prev.astype(jnp.float32), (0, pad))[None, :]
    ycc = jnp.pad(yc_cur.astype(jnp.float32), (0, pad))[None, :]
    prm = params.astype(jnp.float32).reshape(1, 4)
    grid = (n_pad // BLOCK, n_pad // BLOCK)

    out, arg = pl.pallas_call(
        functools.partial(_kernel, n_valid=n, block=BLOCK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda j, i: (0, 0)),          # params
            pl.BlockSpec((1, BLOCK), lambda j, i: (0, i)),      # F (source)
            pl.BlockSpec((1, BLOCK), lambda j, i: (0, i)),      # yc_prev
            pl.BlockSpec((1, BLOCK), lambda j, i: (0, j)),      # yc_cur
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda j, i: (0, j)),
            pl.BlockSpec((1, BLOCK), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        ],
        interpret=interpret,
    )(prm, Fp, ycp, ycc)
    return out[0, :n], arg[0, :n]
