"""Structured (monotone-decomposition) min-plus transition kernel.

Scan-based Pallas variant of `repro.core.dp.minplus_step_structured`: the
same <= 3-segment decomposition (derivation in the core.dp module
docstring), computed as one kernel invocation whose row vectors and scan
tables live in VMEM for the whole step:

  * prefix/suffix segment mins: Hillis-Steele doubling min-scans over the
    (value, index) pairs — log2(N) static rounds of shift + select;
  * middle segment: a doubling (sparse) range-min table built from the
    same strided scans, queried with two overlapping power-of-two blocks;
  * the y_c crossing k(j): branchless vectorized binary search (the
    in-kernel equivalent of searchsorted on the negated levels).

min/argmin combining is exact (no rounding), and every g/h expression
matches the jnp structured path term-for-term, so the kernel's outputs
are bit-identical to `minplus_step_structured` — and to the dense oracle
on monotone y_c inputs. Unlike the dense `minplus` kernel this one does
O(N log N) work, so it exists for VMEM-residency (no per-table HBM
round-trips), not arithmetic-intensity, reasons.

The i axis is padded to a multiple of 128 for lane alignment: F pads with
the large-positive sentinel (never wins a min) and the y_c vectors pad
with their last value (preserves the monotonicity precondition).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dp import _first_min_pair as _first_min

from .minplus import BLOCK, _PAD_HI


def _prefix_min_scan(v, a, log_n: int, reverse: bool):
    """Inclusive running (min, first-argmin) via log_n doubling rounds."""
    n = v.shape[0]
    inf = jnp.float32(jnp.inf)
    for r in range(log_n):
        h = 1 << r
        if reverse:
            sv = jnp.concatenate([v[h:], jnp.full((h,), inf, v.dtype)])
            sa = jnp.concatenate([a[h:], jnp.full((h,), n, a.dtype)])
        else:
            sv = jnp.concatenate([jnp.full((h,), inf, v.dtype), v[:-h]])
            sa = jnp.concatenate([jnp.full((h,), n, a.dtype), a[:-h]])
        v, a = _first_min(v, a, sv, sa)
    return v, a


def _kernel(params_ref, f_ref, ycp_ref, ycc_ref, out_ref, arg_ref, *,
            n_pad: int, log_n: int):
    af = params_ref[0, 0]
    df = params_ref[0, 1]
    ac = params_ref[0, 2]
    dc = params_ref[0, 3]

    F = f_ref[0, :]
    u = ycp_ref[0, :]                     # y_c of the source interval
    v = ycc_ref[0, :]                     # y_c of the destination interval

    i = jax.lax.broadcasted_iota(jnp.float32, (n_pad,), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (n_pad,), 0)
    jf = i                                 # same values, float view
    idx = j

    # Crossing k(j) = |{i : u(i) > v(j)}| (u non-increasing): branchless
    # binary search, log_n static rounds.
    k = jnp.zeros((n_pad,), jnp.int32)
    for r in reversed(range(log_n + 1)):
        cand = k + (1 << r)
        probe = jnp.take(u, jnp.minimum(cand - 1, n_pad - 1))
        move = (cand <= n_pad) & (probe > v)
        k = jnp.where(move, cand, k)
    m1 = jnp.minimum(j, k)
    m2 = jnp.maximum(j, k)

    g1 = F - af * i + dc * u
    g2 = F - af * i - ac * u
    g3 = F + df * i + dc * u
    g4 = F + df * i - ac * u
    inf = jnp.float32(jnp.inf)

    # Prefix [0, m1): exclusive running min of g1, read at m1.
    pv, pa = _prefix_min_scan(g1, idx, log_n, reverse=False)
    pv = jnp.take(jnp.concatenate([jnp.full((1,), inf), pv]), m1)
    pa = jnp.take(jnp.concatenate([jnp.zeros((1,), jnp.int32), pa]), m1)
    pv = pv + (af * jf - dc * v)

    # Suffix [m2, N): exclusive-from-the-right running min of g4.
    sv, sa = _prefix_min_scan(g4, idx, log_n, reverse=True)
    sv = jnp.take(jnp.concatenate([sv, jnp.full((1,), inf)]), m2)
    sa = jnp.take(jnp.concatenate([sa, jnp.zeros((1,), jnp.int32)]), m2)
    sv = sv + (-df * jf + ac * v)

    # Middle [m1, m2): doubling range-min tables of g2 / g3.
    def table(g):
        tv, ta = [g], [idx]
        for r in range(1, log_n + 1):
            h = 1 << (r - 1)
            cv = jnp.concatenate([tv[-1][h:], jnp.full((h,), inf)])
            ca = jnp.concatenate([ta[-1][h:], jnp.full((h,), n_pad,
                                                       jnp.int32)])
            nv, na = _first_min(tv[-1], ta[-1], cv, ca)
            tv.append(nv)
            ta.append(na)
        return jnp.stack(tv).ravel(), jnp.stack(ta).ravel()

    length = m2 - m1
    s = jnp.floor(jnp.log2(jnp.maximum(length, 1).astype(jnp.float32)))
    s = jnp.clip(s.astype(jnp.int32), 0, log_n)
    r2 = jnp.maximum(m2 - jnp.left_shift(1, s), 0)

    def query(g):
        tv, ta = table(g)
        v1, a1 = jnp.take(tv, s * n_pad + m1), jnp.take(ta, s * n_pad + m1)
        v2, a2 = jnp.take(tv, s * n_pad + r2), jnp.take(ta, s * n_pad + r2)
        qv, qa = _first_min(v1, a1, v2, a2)
        return jnp.where(length <= 0, inf, qv), jnp.where(length <= 0, 0, qa)

    mv2, ma2 = query(g2)
    mv3, ma3 = query(g3)
    use_g2 = k <= j
    mv = jnp.where(use_g2, mv2 + (af * jf + ac * v),
                   mv3 + (-df * jf - dc * v))
    ma = jnp.where(use_g2, ma2, ma3)

    # Combine in source-index order; strict < keeps the first minimizer.
    bv, ba = pv, pa
    take = mv < bv
    bv, ba = jnp.where(take, mv, bv), jnp.where(take, ma, ba)
    take = sv < bv
    bv, ba = jnp.where(take, sv, bv), jnp.where(take, sa, ba)
    out_ref[0, :] = bv
    arg_ref[0, :] = ba.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_structured_pallas(F: jnp.ndarray, yc_prev: jnp.ndarray,
                              yc_cur: jnp.ndarray, params: jnp.ndarray,
                              interpret: bool | None = None):
    """F, yc_prev, yc_cur: (N,) float32 with both y_c non-increasing;
    params: (4,) [af, df, ac, dc]. Returns (out, argmin) like the oracle.
    ``interpret=None`` autodetects: compiled where the probed
    `repro.kernels.backend.pallas_mode` is Mosaic/Triton, interpret
    fallback otherwise."""
    if interpret is None:
        from repro.kernels.backend import use_interpret
        interpret = use_interpret()
    n = F.shape[0]
    n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    pad = n_pad - n
    Fp = jnp.pad(F.astype(jnp.float32), (0, pad),
                 constant_values=_PAD_HI)[None, :]
    ycp = jnp.pad(yc_prev.astype(jnp.float32), (0, pad), mode="edge")[None, :]
    ycc = jnp.pad(yc_cur.astype(jnp.float32), (0, pad), mode="edge")[None, :]
    prm = params.astype(jnp.float32).reshape(1, 4)
    log_n = max(1, (n_pad - 1).bit_length())

    out, arg = pl.pallas_call(
        functools.partial(_kernel, n_pad=n_pad, log_n=log_n),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda _: (0, 0)),          # params
            pl.BlockSpec((1, n_pad), lambda _: (0, 0)),      # F
            pl.BlockSpec((1, n_pad), lambda _: (0, 0)),      # yc_prev
            pl.BlockSpec((1, n_pad), lambda _: (0, 0)),      # yc_cur
        ],
        out_specs=[
            pl.BlockSpec((1, n_pad), lambda _: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        ],
        interpret=interpret,
    )(prm, Fp, ycp, ycc)
    return out[0, :n], arg[0, :n]
