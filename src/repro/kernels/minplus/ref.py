"""Pure-jnp oracle for the min-plus DP transition.

The canonical implementation lives in repro.core.dp (the DP uses it
directly when the kernel is disabled); re-exported here so kernel tests
follow the standard kernels/<name>/{ref,ops} layout.
"""

from repro.core.dp import minplus_step_jnp as minplus_step_ref  # noqa: F401
from repro.core.dp import (  # noqa: F401
    minplus_step_structured as minplus_step_structured_ref,
)
