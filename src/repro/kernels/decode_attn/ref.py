"""Pure-jnp oracle: single-step GQA decode attention over a KV cache."""

from __future__ import annotations

import jax.numpy as jnp


def _masked_softmax(scores: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)               # all-masked rows
    e = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m), 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,) valid cache length.

    Hq must be a multiple of Hkv (grouped queries). Returns (B, Hq, D) in
    q's dtype; softmax/accumulation in float32.
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * (d ** -0.5)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)      # (B, Hkv, S, D)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qf, kf)
    mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    w = _masked_softmax(scores)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, vf)
    return out.reshape(b, hq, d).astype(q.dtype)
