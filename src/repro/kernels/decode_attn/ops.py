"""Jit'd wrapper for GQA flash-decode; interpret-mode fallback on CPU.

`decode_attention(q, k, v, lengths)` matches ref.decode_attention_ref.
The serving engine calls this for decode steps when the KV cache is long
enough that the kernel's bandwidth savings matter; otherwise the jnp path
is used (one fused XLA op is faster for tiny caches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attn import decode_attention_pallas
from .ref import decode_attention_ref

# below this cache length the jnp path wins (no VMEM pipeline setup)
MIN_KERNEL_SEQ = 512


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, block_s: int = 256,
                     force_kernel: bool = False) -> jnp.ndarray:
    if not force_kernel and k.shape[1] < MIN_KERNEL_SEQ:
        return decode_attention_ref(q, k, v, lengths)
    return decode_attention_pallas(q, k, v, lengths, block_s=block_s,
                                   interpret=_interpret())
