from .ops import decode_attention  # noqa: F401
