"""GQA flash-decode attention kernel (one new token vs. a long KV cache).

TPU adaptation of flash-decoding: instead of GPU-style warp splits, the
cache sequence axis is tiled into VMEM-resident blocks and reduced with an
online softmax; the grouped queries of one KV head are packed into the
sublane dimension so the (G, D) x (D, S_blk) score matmul runs on the MXU.

Grid: (batch, kv_heads, seq_blocks); batch/head parallel, sequence
innermost (arbitrary) carrying the running max / normalizer / accumulator
in VMEM scratch. Per-sequence lengths mask the tail block and support
ragged batches.

Memory: decode attention is bandwidth-bound (every KV byte is touched once
per token). The roofline win vs the jnp path is avoiding the materialized
(B, Hq, S) score tensor: HBM traffic drops from ~2*S*Hkv*D + S*Hq floats
to the KV read ~2*S*Hkv*D — a (1 + G/(2D))x reduction, and VMEM tiling
keeps the working set on-chip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1.0e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_s: int, scale: float):
    s_blk = pl.program_id(2)
    ns = pl.num_programs(2)
    length = len_ref[0, 0]

    @pl.when(s_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale              # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (block_s, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scores = jax.lax.dot_general(                          # (G, block_s)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    pos = (s_blk * block_s
           + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    scores = jnp.where(pos < length, scores, _NEG)

    m_prev = m_ref[:, 0]                                   # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
    alpha = jnp.exp(m_prev - m_new)                        # (G,)
    p = jnp.exp(scores - m_new[:, None])                   # (G, block_s)
    p = jnp.where(pos < length, p, 0.0)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(                              # (G, D)
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(s_blk == ns - 1)
    def _done():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            lengths: jnp.ndarray, block_s: int = 256,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,). See ref.py."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    assert hq % hkv == 0, "grouped-query heads must divide evenly"
    g = hq // hkv
    block_s = min(block_s, max(s, 1))
    s_pad = ((s + block_s - 1) // block_s) * block_s
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    lens = lengths.astype(jnp.int32).reshape(b, 1)
    grid = (b, hkv, s_pad // block_s)

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),            # len
            pl.BlockSpec((1, g, d), lambda bi, hi, si: (bi, hi, 0)),     # q
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, hi, si: (bi, si, hi, 0)),            # k
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, hi, si: (bi, si, hi, 0)),            # v
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bi, hi, si: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),     # acc
            pltpu.VMEM((g, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((g, 128), jnp.float32),   # running normalizer
        ],
        interpret=interpret,
    )(lens, q, k, v)
    return out
