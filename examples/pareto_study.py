"""Reproduce the paper's §3 insight interactively: sweep the energy/cost
weighting of the optimal hybrid scheduler and print the pareto front at
two burstiness levels (Fig. 3), plus the homogeneous corner points.

The study is batched: work traces for both burstiness levels are built up
front and each platform group solves all its (bias, weight) cells in one
`solve_dp_batch` dispatch — the min-plus DP vmaps over the weight axis,
and each solve runs the structured O(N log N) min-plus transition
(`transition="structured"`, the default; see core.dp for the monotone
segment decomposition) rather than the dense O(N^2) contraction.

Run:  PYTHONPATH=src python examples/pareto_study.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fig2_pareto import interval_work
from repro.core.dp import PARETO_WEIGHTS, solve_dp_batch
from repro.core.metrics import report
from repro.core.workers import DEFAULT_FLEET

BIASES = (0.55, 0.75)


def main() -> None:
    fleet = DEFAULT_FLEET.replace(max_fpgas=2048, max_cpus=10 ** 6)
    work = {bias: interval_work(0, bias, 1800) for bias in BIASES}

    # Corner points: one batch per homogeneous platform (2 cells each).
    corners = {}
    for label, kw in (("CPU-only ", dict(allow_fpga=False)),
                      ("FPGA-only", dict(allow_cpu=False))):
        sols = solve_dp_batch(np.stack([work[b] for b in BIASES]), fleet,
                              [1.0] * len(BIASES), **kw)
        corners[label] = dict(zip(BIASES, sols))

    # Hybrid pareto fronts: all (bias, weight) cells in ONE dispatch,
    # each solved with the structured min-plus transition.
    front_cells = [(bias, float(w)) for bias in BIASES
                   for w in PARETO_WEIGHTS]
    sols = solve_dp_batch(np.stack([work[b] for b, _ in front_cells]), fleet,
                          [w for _, w in front_cells])
    fronts = {bias: [] for bias in BIASES}
    for (bias, w), sol in zip(front_cells, sols):
        fronts[bias].append((w, sol))

    for bias in BIASES:
        print(f"=== burstiness b={bias} ===")
        for label in corners:
            r = report(corners[label][bias].totals, fleet)
            print(f"  {label}: eff={r.energy_efficiency:.3f} "
                  f"cost={r.relative_cost:.3f}")
        print("  hybrid pareto front (w: cost-opt -> energy-opt):")
        for w, sol in fronts[bias]:
            r = report(sol.totals, fleet)
            print(f"    w={w:5.3f} eff={r.energy_efficiency:.3f} "
                  f"cost={r.relative_cost:.3f} "
                  f"peak_fpgas={int(sol.y_fpga.max())}")


if __name__ == "__main__":
    main()
