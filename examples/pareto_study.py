"""Reproduce the paper's §3 insight interactively: sweep the energy/cost
weighting of the optimal hybrid scheduler and print the pareto front at
two burstiness levels (Fig. 3), plus the homogeneous corner points.

Run:  PYTHONPATH=src python examples/pareto_study.py
"""

import numpy as np

from benchmarks.fig2_pareto import interval_work
from repro.core.dp import pareto_front, solve_dp
from repro.core.metrics import report
from repro.core.workers import DEFAULT_FLEET


def main() -> None:
    fleet = DEFAULT_FLEET.replace(max_fpgas=2048, max_cpus=10 ** 6)
    for bias in (0.55, 0.75):
        W = interval_work(0, bias, 1800)
        print(f"=== burstiness b={bias} ===")
        for label, kw in (("CPU-only ", dict(allow_fpga=False)),
                          ("FPGA-only", dict(allow_cpu=False))):
            sol = solve_dp(W, fleet, energy_weight=1.0, **kw)
            r = report(sol.totals, fleet)
            print(f"  {label}: eff={r.energy_efficiency:.3f} "
                  f"cost={r.relative_cost:.3f}")
        print("  hybrid pareto front (w: cost-opt -> energy-opt):")
        for sol, w in zip(pareto_front(W, fleet),
                          [0.0] + list(np.geomspace(0.02, 1.0, 9))):
            r = report(sol.totals, fleet)
            print(f"    w={w:5.3f} eff={r.energy_efficiency:.3f} "
                  f"cost={r.relative_cost:.3f} "
                  f"peak_fpgas={int(sol.y_fpga.max())}")


if __name__ == "__main__":
    main()
