"""End-to-end training example: a ~100M-parameter dense LM for a few
hundred steps with checkpoint/restart, on the public training API.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a scaled granite-family model (~100M params). Loss must
drop substantially from its ~log(V) start; the script resumes from the
latest checkpoint if re-run (kill it mid-way to see restart work).
"""

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train.loop import init_train_state, make_train_step


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="granite-100m", family="dense", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32768,
        mlp_type="swiglu", q_block=256)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=3)
    step_fn = jax.jit(make_train_step(model, base_lr=6e-4, warmup=20,
                                      total_steps=args.steps))
    mgr = CheckpointManager(args.ckpt_dir, every_steps=100)

    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    if mgr.latest() is not None:
        (state,), manifest = mgr.restore((state,))
        start = manifest["step"]
        print(f"resumed from step {start}")

    first_loss = None
    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, pipe.batch_at(step))
        loss = float(metrics["loss"])
        if first_loss is None:
            first_loss = loss
        if step % 25 == 0:
            rate = args.batch * args.seq * (step - start + 1) / (
                time.time() - t0)
            print(f"step {step:4d} loss {loss:7.4f} "
                  f"({rate:,.0f} tok/s)")
        if mgr.should_save(step):
            mgr.save(step, (jax.device_get(state),))
    mgr.save(args.steps, (jax.device_get(state),))
    print(f"done: loss {first_loss:.3f} -> {loss:.3f} "
          f"(drop {first_loss - loss:.3f})")
    assert loss < first_loss - 0.5, "training did not converge"


if __name__ == "__main__":
    main()
