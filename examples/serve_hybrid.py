"""Hybrid serving example: Spork schedules a bursty request stream for a
zoo architecture while a live engine decodes batched requests.

Run:  PYTHONPATH=src python examples/serve_hybrid.py [--arch qwen3-0.6b]

This is the paper's deployment story end-to-end: the router decides WHEN
accelerator workers spin up/down and WHERE each request runs (meeting
10x-size deadlines); the engine shows WHAT each accelerator worker
executes (batched token decoding with a KV cache).
"""

import argparse

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--objective", default="energy",
                    choices=["energy", "cost", "balanced"])
    args = ap.parse_args()
    import sys
    sys.argv = ["serve", "--arch", args.arch, "--minutes", "5",
                "--rate", "30", "--objective", args.objective]
    serve.main()


if __name__ == "__main__":
    main()
