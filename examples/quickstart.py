"""Quickstart: the three layers of the framework in one script.

1. The paper's scheduler: Spork vs homogeneous platforms on a bursty
   trace (energy efficiency + cost, normalized per §5.1).
2. The optimal-scheduler study: min-plus DP pareto point.
3. A model from the assigned zoo: train a smoke config for a few steps
   and decode a few tokens.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.dp import solve_dp
from repro.core.metrics import report
from repro.core.traces import synthetic_trace
from repro.core.workers import DEFAULT_FLEET
from repro.models import build_model
from repro.sim import ratesim
from repro.train.loop import init_train_state, make_train_step


def spork_vs_homogeneous():
    print("=== 1. Spork vs homogeneous platforms (b=0.65, 30 min) ===")
    tr = synthetic_trace(seed=0, bias=0.65, horizon_s=1800,
                         request_size_s=0.05, mean_demand_workers=50.0)
    for policy in ("cpu_dynamic", "fpga_static", "spork", "spork_ideal"):
        r = report(ratesim.simulate(policy, tr.counts, tr.request_size_s,
                                    DEFAULT_FLEET), DEFAULT_FLEET)
        print(f"  {policy:13s} energy_eff={r.energy_efficiency:.3f} "
              f"rel_cost={r.relative_cost:.3f}")


def optimal_study():
    print("=== 2. Pareto-optimal scheduler (perfect information) ===")
    rng = np.random.default_rng(0)
    W = rng.uniform(0, 50 * DEFAULT_FLEET.T_s, size=90)
    for label, ew in (("energy-optimal", 1.0), ("cost-optimal", 0.0)):
        sol = solve_dp(W, DEFAULT_FLEET, energy_weight=ew)
        r = report(sol.totals, DEFAULT_FLEET)
        print(f"  {label:14s} energy_eff={r.energy_efficiency:.3f} "
              f"rel_cost={r.relative_cost:.3f}")


def train_and_decode():
    print("=== 3. Train + decode a zoo model (qwen3-0.6b smoke) ===")
    cfg = get_config("qwen3-0.6b", "smoke")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, total_steps=20))
    rng = np.random.default_rng(0)
    for i in range(10):
        batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                        (4, 64)).astype(np.int32)}
        state, metrics = step(state, batch)
        if i % 3 == 0:
            print(f"  step {i} loss={float(metrics['loss']):.4f}")
    cache = model.init_cache(1, 32)
    tok = np.zeros((1, 1), np.int32)
    toks = []
    for _ in range(8):
        cache, logits = jax.jit(model.decode_step)(state.params, tok, cache)
        tok = np.asarray(logits.argmax(-1)).reshape(1, 1).astype(np.int32)
        toks.append(int(tok[0, 0]))
    print(f"  greedy decode: {toks}")


if __name__ == "__main__":
    spork_vs_homogeneous()
    optimal_study()
    train_and_decode()
